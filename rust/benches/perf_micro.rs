//! §Perf microbenches — the simulator's hot paths, timed, and the numbers
//! recorded to `BENCH_perf.json` so every PR extends a perf trajectory
//! (DESIGN.md §Perf documents the layout and targets: ≥10⁷ synaptic
//! events/s/core on the SDA→EPA hot path).
//!
//! The headline comparison is the fused zero-materialization SDA→EPA
//! stream (`Epa::run_conv_fused`, the default path) against the
//! materializing event-vector path (`PipeSda::process` + `Epa::run_conv`,
//! the validation mode) on the same mid-network layer — both measured in
//! the same run. The packed QKFormer attention register and the packed
//! WTFC TTFS filter are each timed against their byte-map validation
//! walks, and a full qkfresnet11 image pits the packed default against the
//! materializing mode end to end. The host-parallel section times the
//! fused conv scatter fanned out over output-channel blocks. The pipeline
//! section records simulated device cycles for the three-stream pipelined
//! schedule (W-FIFO weight prefetch + A-FIFO activation prescan) against
//! both the serial elastic composition and the weight-only afifo_depth=0
//! schedule, with the hidden/stall/occupancy counters for both FIFOs, and
//! sweeps the wfifo×afifo depth grid on vgg11. The batch section measures
//! how a 16-image batch scales across the coordinator's engine pool from 1
//! to 4 workers, and the weight-DRAM section records the per-image weight
//! stream bytes for a standalone image vs an image inside a 4-image
//! broadcast batch (one modeled fetch per node shared through the
//! `WmuBroadcast` ledger, backed by the pool-shared transposed weight
//! cache) alongside the retired scalar credit's 0.25 reference ratio.
//! The multi-tenant section warms a 2-model, 4-worker pool twice — once
//! with the pool-shared weight cache, once with detached per-worker
//! caches — and records the transpose counts; the shared cache must show
//! ≥ (workers−1)/workers fewer transposes. The sched section drains a
//! backlogged 4-model trace through each `--sched` policy with zero-byte
//! payloads and records the per-request dispatch cost (wfair vs fifo is
//! the fairness-overhead headline). The observability section times the
//! log-bucketed tick histogram against an exact sort at 1M samples, the
//! Chrome trace exporter per recorded request, and the batcher's
//! queue-event log on vs off.

use neural::arch::epa::{ConvParams, ConvScratch, Epa};
use neural::arch::qkformer::{on_the_fly_attention, on_the_fly_attention_bytes};
use neural::arch::sda::{ConvGeom, PipeSda};
use neural::arch::wmu::Wmu;
use neural::arch::wtfc::Wtfc;
use neural::arch::{Accelerator, ElasticFifo, SimScratch, WeightFlow, WmuBroadcast};
use neural::bench::artifacts;
use neural::bench::BenchRunner;
use neural::config::ArchConfig;
use neural::coordinator::{
    Batcher, Engine, EnginePool, InferRequest, ModelId, ModelRegistry, QueueEvent, SchedPolicy,
    TickStats, TraceRecorder,
};
use neural::data::encode_threshold;
use neural::model::exec;
use neural::model::ir::TokenMaskMode;
use neural::model::zoo;
use neural::snn::PackedSpikeMap;
use neural::tensor::{Shape, Tensor};
use neural::util::json::Json;
use neural::util::Pcg32;

fn main() {
    let runner = BenchRunner::from_env();
    println!("== perf_micro (hot paths) ==");

    // raw FIFO ops
    runner.run("fifo push+pop x1M", || {
        let mut f = ElasticFifo::new(64);
        let mut acc = 0u64;
        for i in 0..1_000_000u64 {
            if f.push(i).is_err() {
                while let Some(v) = f.pop() {
                    acc ^= v;
                }
            }
        }
        acc
    });

    // The combined SDA + EPA hot path on a realistic mid-network layer
    // (64ch 16x16, 30% dense, into 128 output channels).
    let mut rng = Pcg32::seeded(3);
    let bits: Vec<u8> = (0..64 * 16 * 16).map(|_| rng.bernoulli(0.3) as u8).collect();
    let map = Tensor::from_vec(Shape::d3(64, 16, 16), bits);
    let packed = PackedSpikeMap::from_map(&map);
    let geom = ConvGeom::new(3, 1, 1, (64, 16, 16));
    let sda = PipeSda::default();
    let weights: Vec<i8> =
        (0..128 * 64 * 9).map(|_| (rng.next_below(15) as i32 - 7) as i8).collect();
    let thresholds = vec![48i32; 128];
    let p = ConvParams {
        cout: 128,
        cin: 64,
        k: 3,
        thresholds: &thresholds,
        tau_half: false,
        weights: &weights,
    };
    let epa = Epa::from_cfg(&ArchConfig::default());
    let events = sda.process(&map, &geom).events.len();
    let sops = events as u64 * 128;

    // materializing path: event vector built, then replayed by the scatter
    let mat = runner.run(&format!("SDA+EPA materializing ({events} events)"), || {
        let out = sda.process(&map, &geom);
        let mut wmu = Wmu::new(8);
        epa.run_conv(&out, &p, &mut wmu, 16, 16).1.sops
    });

    // fused path: packed scan streams straight into the membrane scatter
    let mut scratch = ConvScratch::default();
    let fused = runner.run(&format!("SDA+EPA fused stream ({events} events)"), || {
        let mut wmu = Wmu::new(8);
        epa.run_conv_fused(&sda, &packed, &geom, &p, &mut wmu, &mut scratch).1.sops
    });

    let fused_speedup = mat.time.mean() / fused.time.mean();
    let fused_events_s = events as f64 / fused.time.mean();
    let fused_sops_s = sops as f64 / fused.time.mean();
    println!("  -> fused speedup {fused_speedup:.2}x over materializing");
    println!("  -> {:.1} M diffused events/s fused", fused_events_s / 1e6);
    println!("  -> {:.1} M simulated SOPs/s fused", fused_sops_s / 1e6);

    // Packed QKFormer attention register vs the byte-map validation walk,
    // on the qkfresnet11 stage-2 attention shape (256ch 8x8).
    let qk_bits = |rng: &mut Pcg32, p: f32| -> Vec<u8> {
        (0..256 * 8 * 8).map(|_| rng.bernoulli(p) as u8).collect()
    };
    let q_map = Tensor::from_vec(Shape::d3(256, 8, 8), qk_bits(&mut rng, 0.15));
    let k_map = Tensor::from_vec(Shape::d3(256, 8, 8), qk_bits(&mut rng, 0.4));
    let (q_packed, k_packed) = (PackedSpikeMap::from_map(&q_map), PackedSpikeMap::from_map(&k_map));
    let qkf_byte = runner.run("QKF token mask byte (validation)", || {
        on_the_fly_attention_bytes(&q_map, &k_map, TokenMaskMode::Token).1.passed
    });
    let qkf_packed = runner.run("QKF token mask packed", || {
        on_the_fly_attention(&q_packed, &k_packed, TokenMaskMode::Token).1.passed
    });
    let qkf_speedup = qkf_byte.time.mean() / qkf_packed.time.mean();
    println!("  -> packed QKF speedup {qkf_speedup:.2}x over byte walk");

    // Packed WTFC TTFS filter vs the byte-map walk, on the resnet11
    // terminal shape (512ch 4x4, window 4) with 10 classes.
    let wtfc_bits: Vec<u8> = (0..512 * 16).map(|_| rng.bernoulli(0.3) as u8).collect();
    let wtfc_map = Tensor::from_vec(Shape::d3(512, 4, 4), wtfc_bits);
    let wtfc_packed_map = PackedSpikeMap::from_map(&wtfc_map);
    let fc_weights: Vec<i8> =
        (0..10 * 512).map(|_| (rng.next_below(15) as i32 - 7) as i8).collect();
    let wtfc = Wtfc::from_cfg(&ArchConfig::default());
    let wtfc_byte = runner.run("WTFC filter byte (validation)", || {
        wtfc.run(&wtfc_map, 10, 512, 1, 1, 4, &fc_weights).sops
    });
    let wtfc_packed = runner.run("WTFC filter packed", || {
        wtfc.run_packed(&wtfc_packed_map, 10, 512, 1, 1, 4, &fc_weights).sops
    });
    let wtfc_speedup = wtfc_byte.time.mean() / wtfc_packed.time.mean();
    println!("  -> packed WTFC speedup {wtfc_speedup:.2}x over byte walk");

    // golden conv (gather) on comparable work for reference
    runner.run("golden dense layer (exec conv)", || {
        let (model, _) = artifacts::model_or_zoo("tiny", "none", 10);
        let (img, _) = artifacts::eval_split(10, 1).get(0);
        exec::execute(&model, &encode_threshold(&img, 128)).unwrap().total_sops
    });

    // full-image simulation end to end (fused default path)
    let (model, _) = artifacts::model_or_zoo("resnet11", "c10", 10);
    let ds = artifacts::eval_split(10, 16);
    let (img, _) = ds.get(0);
    let spikes = encode_threshold(&img, 128);
    let acc = Accelerator::new(ArchConfig::default());
    let rep = acc.run(&model, &spikes).unwrap();
    let full = runner.run(
        &format!("full image sim resnet11 ({} SOPs)", rep.activity.sops),
        || acc.run(&model, &spikes).unwrap().activity.sops,
    );
    let full_sops_s = rep.activity.sops as f64 / full.time.mean();
    println!("  -> {:.1} M simulated SOPs/s end-to-end", full_sops_s / 1e6);

    // golden full image for reference
    let gold = runner.run("full image golden resnet11", || {
        exec::execute(&model, &spikes).unwrap().total_sops
    });
    println!(
        "  -> {:.1} M golden SOPs/s end-to-end",
        rep.activity.sops as f64 / gold.time.mean() / 1e6
    );

    // Full-image qkfresnet11: the packed default (fused convs + packed
    // attention register + packed TTFS filter, warm weight cache) against
    // the byte-map materializing validation mode — the PR-gating ratio for
    // the packed QKFormer/WTFC paths.
    let (qkf_model, _) = artifacts::model_or_zoo("qkfresnet11", "c10", 10);
    let acc_mat = Accelerator::materializing(ArchConfig::default());
    let mut sim_scratch = SimScratch::default();
    let qkf_mat = runner.run("full image qkfresnet11 materializing (byte)", || {
        acc_mat.run(&qkf_model, &spikes).unwrap().activity.sops
    });
    let qkf_fused = runner.run("full image qkfresnet11 fused (packed)", || {
        let flow = WeightFlow::Exclusive;
        acc.run_cached(&qkf_model, &spikes, &mut sim_scratch, flow).unwrap().activity.sops
    });
    let qkf_full_speedup = qkf_mat.time.mean() / qkf_fused.time.mean();
    println!("  -> qkfresnet11 packed-path speedup {qkf_full_speedup:.2}x over byte validation");

    // Host-parallel fused scatter: the same full image with the membrane
    // scatter fanned out over output-channel blocks (wall-clock only; the
    // simulated device is bit-identical). Both sides run with a warm
    // per-engine scratch so the speedup isolates the threading, not the
    // weight-cache reuse.
    let host_threads = std::thread::available_parallelism().map_or(4, |n| n.get()).min(8);
    let mut warm_scratch = SimScratch::default();
    let full_warm = runner.run("full image resnet11, 1 host thread (warm)", || {
        let flow = WeightFlow::Exclusive;
        let r = acc.run_cached(&model, &spikes, &mut warm_scratch, flow).unwrap();
        r.activity.sops
    });
    let mut acc_host_par = Accelerator::new(ArchConfig::default());
    acc_host_par.host_threads = host_threads;
    let mut hp_scratch = SimScratch::default();
    let host_par = runner.run(&format!("full image resnet11, {host_threads} host threads"), || {
        let flow = WeightFlow::Exclusive;
        let r = acc_host_par.run_cached(&model, &spikes, &mut hp_scratch, flow).unwrap();
        r.activity.sops
    });
    let host_par_speedup = full_warm.time.mean() / host_par.time.mean();
    println!("  -> host-parallel scatter speedup {host_par_speedup:.2}x over 1 warm thread");

    // Cross-layer pipelined prefetch vs the serial elastic composition
    // (simulated device cycles, not wall-clock): the W-FIFO hides
    // stream-bound layers' weight loads behind earlier compute, and the
    // A-FIFO additionally hides each conv's input-scan slack behind its
    // producer's drain. The afifo_depth=0 run isolates the activation
    // side's contribution on top of the weight-only schedule.
    let mut acc_serial = Accelerator::new(ArchConfig::default());
    acc_serial.pipeline = false;
    let acc_no_a = Accelerator::new(ArchConfig { afifo_depth: 0, ..Default::default() });
    let mut pipeline_sections = Vec::new();
    for m in [&model, &qkf_model] {
        let piped = acc.run(m, &spikes).unwrap();
        let weight_only = acc_no_a.run(m, &spikes).unwrap();
        let serial = acc_serial.run(m, &spikes).unwrap();
        // The strict-improvement invariant itself is enforced by the
        // sim.rs unit tests; here we only record and flag, so a future
        // config rebalance still produces a BENCH_perf.json to diff.
        if piped.cycles >= serial.cycles {
            eprintln!("  !! {}: pipelined schedule did not beat serial", m.name);
        }
        let cycle_speedup = serial.cycles as f64 / piped.cycles as f64;
        let activation_overlap_speedup = weight_only.cycles as f64 / piped.cycles as f64;
        println!(
            "  -> {} pipelined {} cycles vs serial {} ({cycle_speedup:.4}x; wfifo {} hidden / \
             {} stalled, afifo {} hidden / {} stalled, {activation_overlap_speedup:.4}x over \
             weight-only)",
            m.name,
            piped.cycles,
            serial.cycles,
            piped.wfifo.hidden_cycles,
            piped.wfifo.stall_cycles,
            piped.afifo.hidden_cycles,
            piped.afifo.stall_cycles
        );
        pipeline_sections.push((
            m.name.clone(),
            Json::obj(vec![
                ("serial_cycles", Json::Num(serial.cycles as f64)),
                ("pipelined_cycles", Json::Num(piped.cycles as f64)),
                ("weight_only_cycles", Json::Num(weight_only.cycles as f64)),
                ("cycle_speedup", Json::Num(cycle_speedup)),
                ("activation_overlap_speedup", Json::Num(activation_overlap_speedup)),
                ("hidden_cycles", Json::Num(piped.wfifo.hidden_cycles as f64)),
                ("stall_cycles", Json::Num(piped.wfifo.stall_cycles as f64)),
                ("wfifo_high_water_bytes", Json::Num(piped.wfifo.high_water_bytes as f64)),
                ("wfifo_capacity_bytes", Json::Num(piped.wfifo.capacity_bytes as f64)),
                ("afifo_hidden_cycles", Json::Num(piped.afifo.hidden_cycles as f64)),
                ("afifo_stall_cycles", Json::Num(piped.afifo.stall_cycles as f64)),
                ("afifo_high_water_bytes", Json::Num(piped.afifo.high_water_bytes as f64)),
                ("afifo_capacity_bytes", Json::Num(piped.afifo.capacity_bytes as f64)),
            ]),
        ));
    }

    // W-FIFO x A-FIFO depth sweep on vgg11 (simulated cycles): how the two
    // elastic capacities compose on the zoo's most stream-bound CNN — the
    // buffer-sizing view for the two knobs (`wfifo_depth` entries vs
    // `afifo_depth` scan beats). One warm SimScratch serves every point;
    // the device schedule is independent of the host cache.
    let sweep_model = zoo::vgg11(10, 3);
    let mut sweep_scratch = SimScratch::default();
    let wfifo_depths = [0usize, 32, 128];
    let afifo_depths = [0usize, 2048, 8192];
    let mut sweep_rows = Vec::new();
    println!("  -> vgg11 wfifo x afifo depth sweep (cycles):");
    for &wd in &wfifo_depths {
        for &ad in &afifo_depths {
            let cfg = ArchConfig { wfifo_depth: wd, afifo_depth: ad, ..Default::default() };
            let r = Accelerator::new(cfg)
                .run_cached(&sweep_model, &spikes, &mut sweep_scratch, WeightFlow::Exclusive)
                .unwrap();
            println!(
                "     wfifo={wd:>3} afifo={ad:>4}: {} cycles ({} w-hidden, {} a-hidden)",
                r.cycles, r.wfifo.hidden_cycles, r.afifo.hidden_cycles
            );
            sweep_rows.push(Json::obj(vec![
                ("wfifo_depth", Json::Num(wd as f64)),
                ("afifo_depth", Json::Num(ad as f64)),
                ("cycles", Json::Num(r.cycles as f64)),
                ("wfifo_hidden_cycles", Json::Num(r.wfifo.hidden_cycles as f64)),
                ("afifo_hidden_cycles", Json::Num(r.afifo.hidden_cycles as f64)),
            ]));
        }
    }

    // Broadcast-WMU weight-stream sharing vs the retired scalar credit:
    // per-image weight DRAM bytes for a standalone image vs an image inside
    // a 4-image broadcast batch (one modeled fetch per node, fanned out).
    let single_rep =
        acc.run_cached(&qkf_model, &spikes, &mut sim_scratch, WeightFlow::Exclusive).unwrap();
    let shared = WmuBroadcast::new(4);
    let mut batch4_rep = None;
    for _ in 0..4 {
        let flow = WeightFlow::Broadcast(&shared);
        batch4_rep = Some(acc.run_cached(&qkf_model, &spikes, &mut sim_scratch, flow).unwrap());
    }
    let batch4_rep = batch4_rep.unwrap();
    let weight_dram_ratio =
        batch4_rep.weight_dram_bytes as f64 / single_rep.weight_dram_bytes as f64;
    let credit_ratio = 0.25; // what the retired scalar 1/n credit would claim
    println!(
        "  -> weight DRAM/image: {} B single, {} B in 4-broadcast ({weight_dram_ratio:.3}x, \
         scalar credit would say {credit_ratio:.2}x; ledger: {} B, {} fetches)",
        single_rep.weight_dram_bytes,
        batch4_rep.weight_dram_bytes,
        shared.dram_bytes(),
        shared.transactions()
    );

    // coordinator batch path: 16-image batch across the engine pool
    let n = 16.min(ds.len());
    let reqs: Vec<InferRequest> = (0..n)
        .map(|i| {
            let (img, label) = ds.get(i);
            InferRequest {
                id: i as u64,
                model: ModelId(0),
                spikes: encode_threshold(&img, 128),
                label: Some(label),
                arrival_tick: 0,
            }
        })
        .collect();
    let mut batch_ms = Vec::new();
    let worker_counts = [1usize, 4];
    for &w in &worker_counts {
        let pool = EnginePool::new(Engine::sim(model.clone(), ArchConfig::default()), w);
        let r = runner.run(&format!("batch {n} images, {w} worker(s)"), || {
            pool.run_batch(&reqs).len()
        });
        batch_ms.push(r.time.mean() * 1e3);
    }
    let batch_speedup = batch_ms[0] / batch_ms[1];
    println!("  -> batch speedup 1->4 workers: {batch_speedup:.2}x");

    // Multi-tenant shared weight cache: a 2-model, 4-worker warmup batch.
    // The pool-shared cache transposes each (model, conv) once per POOL;
    // the per-worker reference re-transposes per worker that touches the
    // model — the acceptance bound is >= (workers-1)/workers fewer
    // transposes. Requests alternate models so every worker's chunk holds
    // both tenants; singleton broadcast groups keep the mixed dispatch
    // model-homogeneous per domain.
    let cache_workers = 4usize;
    let mt_registry = || {
        let mut reg = ModelRegistry::new();
        reg.register(zoo::resnet11(10, 3), 1);
        reg.register(zoo::qkfresnet11(10, 3), 1);
        reg
    };
    let mt_reqs: Vec<InferRequest> = (0..16)
        .map(|i| {
            let (img, label) = ds.get(i % ds.len());
            InferRequest {
                id: i as u64,
                model: ModelId(i % 2),
                spikes: encode_threshold(&img, 128),
                label: Some(label),
                arrival_tick: 0,
            }
        })
        .collect();
    let mt_groups = vec![1usize; mt_reqs.len()];
    let shared_pool =
        EnginePool::new(Engine::sim_registry(mt_registry(), ArchConfig::default()), cache_workers);
    let shared_warm = runner.run("2-model warmup, 4 workers, shared cache", || {
        shared_pool.run_batch_grouped(&mt_reqs, &mt_groups).len()
    });
    let shared_stats = shared_pool.cache_stats().expect("sim pool has a cache");
    let private_pool = EnginePool::new_private_caches(
        Engine::sim_registry(mt_registry(), ArchConfig::default()),
        cache_workers,
    );
    let private_warm = runner.run("2-model warmup, 4 workers, private caches", || {
        private_pool.run_batch_grouped(&mt_reqs, &mt_groups).len()
    });
    let private_stats = private_pool.cache_stats().expect("sim pool has a cache");
    let transpose_reduction = if private_stats.misses == 0 {
        0.0
    } else {
        1.0 - shared_stats.misses as f64 / private_stats.misses as f64
    };
    let acceptance = (cache_workers as f64 - 1.0) / cache_workers as f64;
    println!(
        "  -> shared cache: {} transposes vs {} per-worker ({:.0}% fewer; bound {:.0}%)",
        shared_stats.misses,
        private_stats.misses,
        transpose_reduction * 100.0,
        acceptance * 100.0
    );
    if transpose_reduction + 1e-9 < acceptance {
        eprintln!("  !! shared cache reduction below the (workers-1)/workers bound");
    }

    // Scheduler dispatch overhead: a 4-model trace pushed through the
    // batcher's full push → pop_ready → flush cycle under each policy
    // (zero-byte payloads, so the numbers isolate the scheduling decision
    // cost, not simulation). The headline is the wfair-vs-fifo dispatch
    // cost ratio — the price of fairness per scheduled request.
    let sched_models = 4usize;
    let sched_bs = 8usize;
    let sched_n = 2048usize;
    let sched_trace: Vec<InferRequest> = (0..sched_n)
        .map(|i| InferRequest {
            id: i as u64,
            model: ModelId(i % sched_models),
            spikes: Tensor::zeros(Shape::d3(1, 1, 1)),
            label: None,
            arrival_tick: 0,
        })
        .collect();
    let sched_policies: Vec<(&str, SchedPolicy)> = vec![
        ("fifo", SchedPolicy::FifoById),
        ("wfair", SchedPolicy::WeightedFair { weights: vec![4, 2, 1, 1] }),
        ("deadline", SchedPolicy::DeadlineAging { deadline: 16 }),
    ];
    let mut sched_ns_per_req = Vec::new();
    for (name, policy) in &sched_policies {
        let r = runner.run(&format!("sched drain {sched_n} reqs ({name})"), || {
            let mut b = Batcher::with_policy(sched_bs, policy.clone());
            let mut out = 0usize;
            for req in sched_trace.iter().cloned() {
                b.push(req);
                while let Some(batch) = b.pop_ready() {
                    out += batch.len();
                }
            }
            while let Some(batch) = b.flush() {
                out += batch.len();
            }
            assert_eq!(out, sched_n);
            out
        });
        sched_ns_per_req.push(r.time.mean() * 1e9 / sched_n as f64);
    }
    let sched_wfair_vs_fifo = sched_ns_per_req[1] / sched_ns_per_req[0].max(1e-12);
    println!(
        "  -> sched dispatch ns/req: fifo {:.0}, wfair {:.0} ({sched_wfair_vs_fifo:.2}x), \
         deadline {:.0}",
        sched_ns_per_req[0], sched_ns_per_req[1], sched_ns_per_req[2]
    );

    // Observability: the log-bucketed tick histogram (constant memory,
    // <= 1/128 relative percentile error) against an exact sort at the
    // same scale, the Chrome trace exporter's cost per recorded request,
    // and the batcher's queue-event log on vs off — the "tracing disabled
    // is (near) zero overhead" claim, measured.
    let obs_n = 1_000_000usize;
    let mut obs_rng = Pcg32::seeded(11);
    let obs_samples: Vec<u64> =
        (0..obs_n).map(|_| 1 + obs_rng.next_below(1 << 20) as u64).collect();
    let hist = runner.run("tick histogram add 1M + p50/p95/p99", || {
        let mut h = TickStats::default();
        for &s in &obs_samples {
            h.add(s);
        }
        h.percentiles(&[50.0, 95.0, 99.0])[2]
    });
    let sort_ref = runner.run("exact percentile via sort 1M (reference)", || {
        let mut v = obs_samples.clone();
        v.sort_unstable();
        v[v.len() * 99 / 100]
    });
    let hist_vs_sort = sort_ref.time.mean() / hist.time.mean();
    println!("  -> histogram percentiles {hist_vs_sort:.2}x faster than sort at 1M samples");

    let trace_reqs = 4096u64;
    let mut obs_rec = TraceRecorder::new();
    for id in 0..trace_reqs {
        let model = ModelId((id % 2) as usize);
        obs_rec.record_queue_event(&QueueEvent::Admitted { id, model, tick: id + 1 });
        obs_rec.record_queue_event(&QueueEvent::Released {
            id,
            model,
            arrival: id + 1,
            release: id + 2,
            completion: id + 3,
            forced: false,
        });
        obs_rec.record_completed(id, model, 0, &[]);
    }
    let trace_bytes = obs_rec.to_chrome_json().len();
    let export = runner.run(&format!("trace export {trace_reqs} requests"), || {
        obs_rec.to_chrome_json().len()
    });
    let export_us_per_req = export.time.mean() * 1e6 / trace_reqs as f64;
    println!(
        "  -> trace export {export_us_per_req:.2} us/request ({trace_bytes} B for {trace_reqs} \
         requests)"
    );

    let mut event_log_ns_per_req = Vec::new();
    for log in [false, true] {
        let tag = if log { "on" } else { "off" };
        let r = runner.run(&format!("batcher drain {sched_n} reqs, event log {tag}"), || {
            let mut b = Batcher::with_policy(sched_bs, SchedPolicy::FifoById);
            if log {
                b.enable_event_log();
            }
            let mut out = 0usize;
            for req in sched_trace.iter().cloned() {
                b.push(req);
                while let Some(batch) = b.pop_ready() {
                    out += batch.len();
                }
            }
            while let Some(batch) = b.flush() {
                out += batch.len();
            }
            out + b.take_events().len()
        });
        event_log_ns_per_req.push(r.time.mean() * 1e9 / sched_n as f64);
    }
    println!(
        "  -> batcher event log ns/req: off {:.0}, on {:.0}",
        event_log_ns_per_req[0], event_log_ns_per_req[1]
    );

    // record the trajectory point
    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_micro".into())),
        (
            "sda_epa",
            Json::obj(vec![
                ("events", Json::Num(events as f64)),
                ("sops", Json::Num(sops as f64)),
                ("materializing_ms", Json::Num(mat.time.mean() * 1e3)),
                ("fused_ms", Json::Num(fused.time.mean() * 1e3)),
                ("fused_speedup", Json::Num(fused_speedup)),
                ("fused_events_per_s", Json::Num(fused_events_s)),
                ("fused_sops_per_s", Json::Num(fused_sops_s)),
            ]),
        ),
        (
            "qkformer",
            Json::obj(vec![
                ("byte_ms", Json::Num(qkf_byte.time.mean() * 1e3)),
                ("packed_ms", Json::Num(qkf_packed.time.mean() * 1e3)),
                ("packed_speedup", Json::Num(qkf_speedup)),
            ]),
        ),
        (
            "wtfc",
            Json::obj(vec![
                ("byte_ms", Json::Num(wtfc_byte.time.mean() * 1e3)),
                ("packed_ms", Json::Num(wtfc_packed.time.mean() * 1e3)),
                ("packed_speedup", Json::Num(wtfc_speedup)),
            ]),
        ),
        (
            "full_image",
            Json::obj(vec![
                ("model", Json::Str(model.name.clone())),
                ("sim_ms", Json::Num(full.time.mean() * 1e3)),
                ("sops", Json::Num(rep.activity.sops as f64)),
                ("sim_sops_per_s", Json::Num(full_sops_s)),
            ]),
        ),
        (
            "qkfresnet11_full",
            Json::obj(vec![
                ("materializing_ms", Json::Num(qkf_mat.time.mean() * 1e3)),
                ("fused_ms", Json::Num(qkf_fused.time.mean() * 1e3)),
                ("packed_speedup", Json::Num(qkf_full_speedup)),
            ]),
        ),
        ("pipeline", Json::Obj(pipeline_sections.into_iter().collect())),
        (
            "pipeline_sweep",
            Json::obj(vec![
                ("model", Json::Str(sweep_model.name.clone())),
                ("rows", Json::Arr(sweep_rows)),
            ]),
        ),
        (
            "host_parallel",
            Json::obj(vec![
                ("threads", Json::Num(host_threads as f64)),
                ("serial_ms", Json::Num(full_warm.time.mean() * 1e3)),
                ("parallel_ms", Json::Num(host_par.time.mean() * 1e3)),
                ("speedup", Json::Num(host_par_speedup)),
            ]),
        ),
        (
            "weight_dram",
            Json::obj(vec![
                ("per_image_bytes_single", Json::Num(single_rep.weight_dram_bytes as f64)),
                ("per_image_bytes_batch4", Json::Num(batch4_rep.weight_dram_bytes as f64)),
                ("batch4_ratio", Json::Num(weight_dram_ratio)),
                ("scalar_credit_ratio", Json::Num(credit_ratio)),
                ("broadcast_ledger_bytes", Json::Num(shared.dram_bytes() as f64)),
                ("broadcast_ledger_fetches", Json::Num(shared.transactions() as f64)),
            ]),
        ),
        (
            "batch",
            Json::obj(vec![
                ("images", Json::Num(n as f64)),
                (
                    "workers",
                    Json::Arr(worker_counts.iter().map(|&w| Json::Num(w as f64)).collect()),
                ),
                ("ms", Json::Arr(batch_ms.iter().map(|&m| Json::Num(m)).collect())),
                ("speedup_1_to_4", Json::Num(batch_speedup)),
            ]),
        ),
        (
            "shared_weight_cache",
            Json::obj(vec![
                ("workers", Json::Num(cache_workers as f64)),
                ("models", Json::Num(2.0)),
                ("shared_transposes", Json::Num(shared_stats.misses as f64)),
                ("private_transposes", Json::Num(private_stats.misses as f64)),
                ("transpose_reduction", Json::Num(transpose_reduction)),
                ("acceptance_bound", Json::Num(acceptance)),
                ("shared_warmup_ms", Json::Num(shared_warm.time.mean() * 1e3)),
                ("private_warmup_ms", Json::Num(private_warm.time.mean() * 1e3)),
                ("resident_bytes", Json::Num(shared_stats.resident_bytes as f64)),
            ]),
        ),
        (
            "sched",
            Json::obj(vec![
                ("models", Json::Num(sched_models as f64)),
                ("batch", Json::Num(sched_bs as f64)),
                ("requests", Json::Num(sched_n as f64)),
                ("fifo_ns_per_req", Json::Num(sched_ns_per_req[0])),
                ("wfair_ns_per_req", Json::Num(sched_ns_per_req[1])),
                ("deadline_ns_per_req", Json::Num(sched_ns_per_req[2])),
                ("wfair_vs_fifo", Json::Num(sched_wfair_vs_fifo)),
            ]),
        ),
        (
            "observability",
            Json::obj(vec![
                ("hist_samples", Json::Num(obs_n as f64)),
                ("hist_add_query_ms", Json::Num(hist.time.mean() * 1e3)),
                ("sort_reference_ms", Json::Num(sort_ref.time.mean() * 1e3)),
                ("hist_vs_sort_speedup", Json::Num(hist_vs_sort)),
                ("trace_requests", Json::Num(trace_reqs as f64)),
                ("trace_export_ms", Json::Num(export.time.mean() * 1e3)),
                ("trace_export_bytes", Json::Num(trace_bytes as f64)),
                ("trace_export_us_per_req", Json::Num(export_us_per_req)),
                ("event_log_off_ns_per_req", Json::Num(event_log_ns_per_req[0])),
                ("event_log_on_ns_per_req", Json::Num(event_log_ns_per_req[1])),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_perf.json", doc.to_text() + "\n") {
        Ok(()) => println!("wrote BENCH_perf.json"),
        Err(e) => eprintln!("could not write BENCH_perf.json: {e}"),
    }
}
