//! Fig 9 — resource (kLUTs) + accuracy of VGG-11 / ResNet-11 on
//! SynthCIFAR-10/100 across platforms (NEURAL vs SiBrain vs SCPU).
//!
//! NEURAL's LUTs come from the analytic model; the baselines use their
//! published implementations' totals (they are fixed silicon, not
//! something we re-synthesize). Accuracy: all platforms execute the same
//! trained weights functionally — the paper's accuracy edge comes from
//! its single-timestep KD models, represented here by our KD-QAT weights;
//! baseline rows show their papers' reported accuracy for reference.

use neural::arch::ResourceModel;
use neural::baselines::BaselineKind;
use neural::bench::artifacts;
use neural::config::ArchConfig;
use neural::util::Table;

fn main() {
    let neural_kluts = ResourceModel::default().evaluate(&ArchConfig::default()).total().luts / 1000.0;
    let mut t = Table::new(
        "Fig 9 — resources & accuracy per platform (measured | paper)",
        &["platform", "kLUTs", "model", "dataset", "acc (ours)", "acc (paper)"],
    );

    // paper-reported accuracy rows for the compared platforms (CIFAR-10).
    let paper_rows = [
        ("SiBrain", BaselineKind::SiBrain.kluts(), "vgg11", "90.25%"),
        ("SCPU", BaselineKind::Scpu.kluts(), "resnet11", "87.19%"),
    ];

    for (classes, tag) in [(10usize, "c10"), (100usize, "c100")] {
        let ds = artifacts::eval_split(classes, 64);
        for name in ["vgg11", "resnet11"] {
            let (model, trained) = artifacts::model_or_zoo(name, tag, classes);
            let acc = artifacts::accuracy(&model, &ds, 64).unwrap();
            let ours = if trained {
                format!("{:.1}%", acc * 100.0)
            } else {
                format!("{:.1}% (untrained zoo)", acc * 100.0)
            };
            let paper = match (name, tag) {
                ("vgg11", "c10") => "93.45%",
                ("vgg11", "c100") => "72.1%",
                ("resnet11", "c10") => "91.87%",
                ("resnet11", "c100") => "66.94%",
                _ => "-",
            };
            t.row(&[
                "NEURAL".into(),
                format!("{neural_kluts:.0}"),
                name.into(),
                tag.into(),
                ours,
                paper.into(),
            ]);
        }
    }
    for (plat, kluts, model, acc) in paper_rows {
        t.row(&[
            plat.into(),
            format!("{kluts:.0}"),
            model.into(),
            "c10".into(),
            "(same weights run functionally)".into(),
            acc.into(),
        ]);
    }
    t.print();
    println!(
        "\nshape check: NEURAL {neural_kluts:.0} kLUTs vs SiBrain {} / SCPU {} — ~50% reduction (paper's claim)",
        BaselineKind::SiBrain.kluts(),
        BaselineKind::Scpu.kluts()
    );
}
