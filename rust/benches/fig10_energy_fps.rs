//! Fig 10 — energy per inference and FPS of VGG-11 / ResNet-11 on
//! SynthCIFAR-10/100 across platforms (NEURAL vs SiBrain vs SCPU).
//!
//! All platforms simulate the *same trained weights* on the same images;
//! what differs is the execution model (timesteps, sparsity awareness,
//! elasticity) and the calibrated power constants. The paper's numbers
//! are printed per row; the claim under test is the *shape*: NEURAL
//! roughly halves energy and raises FPS.

use neural::arch::Accelerator;
use neural::baselines::{Baseline, BaselineKind};
use neural::bench::artifacts;
use neural::config::ArchConfig;
use neural::data::encode_threshold;
use neural::util::{Summary, Table};

fn main() {
    let n_images = if std::env::var("NEURAL_BENCH_FAST").is_ok() { 2 } else { 8 };
    let mut t = Table::new(
        "Fig 10 — energy/inference (mJ) and FPS per platform",
        &["model", "dataset", "platform", "energy mJ", "FPS", "paper (E, FPS)"],
    );
    for (classes, tag) in [(10usize, "c10"), (100usize, "c100")] {
        let ds = artifacts::eval_split(classes, n_images);
        for name in ["vgg11", "resnet11"] {
            let (model, _) = artifacts::model_or_zoo(name, tag, classes);
            let paper = match (name, tag) {
                ("vgg11", "c10") => "~10, 68",
                ("resnet11", "c10") => "5.56, 136",
                ("resnet11", "c100") => "6.44, 133",
                _ => "-",
            };
            // NEURAL
            let acc = Accelerator::new(ArchConfig::default());
            let mut e = Summary::new();
            let mut ms = Summary::new();
            for i in 0..n_images.min(ds.len()) {
                let (img, _) = ds.get(i);
                let rep = acc.run(&model, &encode_threshold(&img, 128)).unwrap();
                e.add(rep.energy.total_j() * 1e3);
                ms.add(rep.latency_ms);
            }
            t.row(&[
                name.into(),
                tag.into(),
                "NEURAL".into(),
                format!("{:.2}", e.mean()),
                format!("{:.0}", 1000.0 / ms.mean()),
                paper.into(),
            ]);
            // baselines
            for kind in [BaselineKind::SiBrain, BaselineKind::Scpu] {
                let b = Baseline::new(kind, ArchConfig::default());
                let mut e = Summary::new();
                let mut ms = Summary::new();
                for i in 0..n_images.min(ds.len()) {
                    let (img, _) = ds.get(i);
                    let rep = b.run(&model, &encode_threshold(&img, 128)).unwrap();
                    e.add(rep.energy.total_j() * 1e3);
                    ms.add(rep.latency_ms);
                }
                t.row(&[
                    name.into(),
                    tag.into(),
                    kind.name().into(),
                    format!("{:.2}", e.mean()),
                    format!("{:.0}", 1000.0 / ms.mean()),
                    "-".into(),
                ]);
            }
        }
    }
    t.print();
    println!("\nshape check (paper): NEURAL cuts energy ~50% vs SiBrain/SCPU and raises FPS.");
}
