//! Table I — hardware resource cost of NEURAL per module.
//!
//! Regenerated from the analytic resource model (`arch/resource.rs`),
//! whose coefficients are calibrated on the default 16×16-EPA geometry;
//! the paper's Vivado numbers are printed alongside. A geometry sweep
//! shows how the model extrapolates.

use neural::arch::ResourceModel;
use neural::config::ArchConfig;
use neural::util::Table;

fn main() {
    let model = ResourceModel::default();
    let report = model.evaluate(&ArchConfig::default());
    let total = report.total();

    let mut t = Table::new(
        "Table I — Hardware Resource Cost of NEURAL (measured = analytic model)",
        &["Resource", "PipeSDA", "EPA", "WTFC", "Total", "paper Total"],
    );
    let k = |x: f64| format!("{:.0}K", x / 1000.0);
    t.row(&["LUTs".into(), k(report.pipesda.luts), k(report.epa.luts), k(report.wtfc.luts), k(total.luts), "74K".into()]);
    t.row(&["Registers".into(), k(report.pipesda.regs), k(report.epa.regs), k(report.wtfc.regs), k(total.regs), "63K".into()]);
    t.row(&[
        "BRAM".into(),
        format!("{}", report.pipesda.bram),
        format!("{}", report.epa.bram),
        format!("{}", report.wtfc.bram),
        format!("{}", total.bram),
        "137.5".into(),
    ]);
    t.print();
    println!("paper per-module: PipeSDA 9K/10K/3, EPA 33K/15K/64, WTFC 1K/0.7K/25\n");

    let mut sweep = Table::new(
        "geometry sweep (model extrapolation)",
        &["EPA", "LUTs", "Registers", "BRAM"],
    );
    for (r, c) in [(8usize, 8usize), (16, 16), (32, 32)] {
        let cfg = ArchConfig { epa_rows: r, epa_cols: c, ..Default::default() };
        let rep = model.evaluate(&cfg).total();
        sweep.row(&[
            format!("{r}x{c}"),
            format!("{:.0}K", rep.luts / 1000.0),
            format!("{:.0}K", rep.regs / 1000.0),
            format!("{:.1}", rep.bram),
        ]);
    }
    sweep.print();
}
