//! Fixture suite: every `trip_*.rs` fixture must produce at least one
//! finding of its rule, and every `pass_*.rs` twin must produce zero
//! findings — under the same `fixtures.toml` config CI uses for the
//! trip-fixture loop.

use std::path::PathBuf;

use detlint::{any_deny, lint_paths, Config};

fn fixture_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn fixture_cfg() -> Config {
    Config::from_path(&fixture_dir().join("fixtures.toml")).expect("fixtures.toml parses")
}

fn lint_fixture(name: &str) -> Vec<detlint::Finding> {
    let path = fixture_dir().join(name);
    lint_paths(&[path], &fixture_cfg()).expect("fixture file reads")
}

/// (trip fixture, rule it must report)
const TRIPS: [(&str, &str); 7] = [
    ("trip_wall_clock.rs", "wall-clock"),
    ("trip_trace_wall_clock.rs", "wall-clock"),
    ("trip_unordered_iter.rs", "unordered-iter"),
    ("trip_unseeded_rng.rs", "unseeded-rng"),
    ("trip_dispatch_unwrap.rs", "dispatch-unwrap"),
    ("trip_worker_dep.rs", "worker-dependent-decision"),
    ("trip_allow_marker.rs", detlint::MALFORMED_ALLOW),
];

const PASSES: [&str; 8] = [
    "pass_wall_clock.rs",
    "pass_trace_wall_clock.rs",
    "pass_unordered_iter.rs",
    "pass_unseeded_rng.rs",
    "pass_dispatch_unwrap.rs",
    "pass_worker_dep.rs",
    "pass_allow_marker.rs",
    "pass_test_code.rs",
];

#[test]
fn every_trip_fixture_trips_its_rule() {
    for (name, rule) in TRIPS {
        let findings = lint_fixture(name);
        assert!(
            findings.iter().any(|f| f.rule == rule),
            "{name} must report {rule}, got: {findings:?}"
        );
        assert!(any_deny(&findings), "{name} findings must be deny severity");
    }
}

#[test]
fn every_pass_fixture_is_clean() {
    for name in PASSES {
        let findings = lint_fixture(name);
        assert!(findings.is_empty(), "{name} must be clean, got: {findings:?}");
    }
}

#[test]
fn every_trip_fixture_has_a_pass_twin_on_disk() {
    for (trip, _) in TRIPS {
        let twin = trip.replacen("trip_", "pass_", 1);
        assert!(
            fixture_dir().join(&twin).is_file(),
            "{trip} is missing its fixed twin {twin}"
        );
    }
}

#[test]
fn bare_allow_marker_fails_to_suppress() {
    let findings = lint_fixture("trip_allow_marker.rs");
    assert!(
        findings.iter().any(|f| f.rule == "unordered-iter"),
        "a reasonless marker must not suppress the underlying rule: {findings:?}"
    );
}

#[test]
fn directory_walk_skips_fixtures_dir() {
    // Linting the crate root must not descend into fixtures/ (which trips
    // rules by design) — only explicit fixture paths are linted.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let findings = lint_paths(&[root], &fixture_cfg()).expect("crate tree reads");
    assert!(
        findings.is_empty(),
        "detlint's own sources must be clean and fixtures skipped: {findings:?}"
    );
}
