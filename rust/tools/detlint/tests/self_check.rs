//! Self-check: the shipped `rust/src` tree must be clean under the
//! shipped `rust/detlint.toml`. This is the same invocation CI runs as
//! a gate (`cargo run -p detlint -- --config detlint.toml src`), kept
//! here too so `cargo test -p detlint` alone catches regressions.

use std::path::PathBuf;

use detlint::{lint_paths, Config};

fn rust_root() -> PathBuf {
    // tools/detlint -> tools -> rust
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .and_then(|p| p.parent())
        .expect("detlint lives at rust/tools/detlint")
        .to_path_buf()
}

#[test]
fn shipped_src_tree_is_clean() {
    let root = rust_root();
    let cfg = Config::from_path(&root.join("detlint.toml")).expect("shipped detlint.toml parses");
    let findings = lint_paths(&[root.join("src")], &cfg).expect("src tree reads");
    assert!(
        findings.is_empty(),
        "determinism-invariant violations in shipped src:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn shipped_config_keeps_all_rules_deny() {
    let cfg = Config::from_path(&rust_root().join("detlint.toml")).expect("config parses");
    for rule in detlint::RULES {
        // Rules may scope or allowlist, but none may be softened below deny
        // without a PR that changes this test too.
        let sev = {
            let mut c = cfg.clone();
            c.rule_mut(rule).severity
        };
        assert_eq!(sev, detlint::Severity::Deny, "{rule} must stay deny");
    }
}
