//! detlint — determinism-invariant static analysis for the NEURAL tree.
//!
//! Every guarantee the coordinator advertises — bit-identical results
//! across worker counts, a virtual clock that never reads wall time,
//! fault decisions that are pure functions of `(request_id, arrival_tick,
//! attempt)` — is enforced here as a machine-checked pass over `rust/src`.
//! Five rules:
//!
//! | rule id                     | forbids                                             |
//! |-----------------------------|-----------------------------------------------------|
//! | `wall-clock`                | `Instant` / `SystemTime` outside the allowlist      |
//! | `unordered-iter`            | `HashMap` / `HashSet` state (use `BTreeMap`)        |
//! | `unseeded-rng`              | entropy-seeded randomness outside `util/rng`        |
//! | `dispatch-unwrap`           | `unwrap`/`expect`/`panic!` in the supervised path   |
//! | `worker-dependent-decision` | worker/thread identity in fault or sched decisions  |
//!
//! The pass is lexical, not syntactic (`syn` is not in the offline vendor
//! set): sources are scrubbed — comments, string literals and char
//! literals blanked with line numbers preserved — then `#[cfg(test)]` /
//! `#[test]` brace regions are skipped, and each rule matches whole
//! identifiers against the surviving code. That is precise enough for the
//! five rule classes (they all key on identifier tokens), and a lexical
//! pass can never be confused by macro expansion it cannot see.
//!
//! Escape hatch: a `// detlint::allow(rule-id, reason)` comment suppresses
//! that rule on its own line and the next. The reason is mandatory — a
//! bare marker is itself reported (`malformed-allow`).
//!
//! Determinism of the lint itself: files are walked in sorted order and
//! findings are sorted `(file, line, column, rule)`, so output is
//! bit-identical across platforms and runs.

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// The five rule identifiers, in report order.
pub const RULES: [&str; 5] = [
    "wall-clock",
    "unordered-iter",
    "unseeded-rng",
    "dispatch-unwrap",
    "worker-dependent-decision",
];

/// Pseudo-rule reported for a `detlint::allow` marker with no reason or an
/// unknown rule id. Always deny — a broken suppression must never pass.
pub const MALFORMED_ALLOW: &str = "malformed-allow";

/// Per-rule enforcement level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Findings fail the run (non-zero exit).
    Deny,
    /// Findings print but never fail the run.
    Warn,
    /// Rule disabled.
    Off,
}

impl Severity {
    fn parse(s: &str) -> Result<Severity, String> {
        match s {
            "deny" => Ok(Severity::Deny),
            "warn" => Ok(Severity::Warn),
            "off" => Ok(Severity::Off),
            other => Err(format!("unknown severity {other:?} (deny|warn|off)")),
        }
    }

    /// The configured name (`deny`/`warn`/`off`).
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Deny => "deny",
            Severity::Warn => "warn",
            Severity::Off => "off",
        }
    }
}

/// One rule's configuration.
#[derive(Debug, Clone)]
pub struct RuleCfg {
    /// Enforcement level.
    pub severity: Severity,
    /// Path substrings exempt from the rule (normalized `/` separators).
    pub allow: Vec<String>,
    /// For scoped rules (`dispatch-unwrap`, `worker-dependent-decision`):
    /// path substrings the rule applies to. Empty = applies everywhere.
    pub paths: Vec<String>,
}

impl Default for RuleCfg {
    fn default() -> Self {
        RuleCfg { severity: Severity::Deny, allow: Vec::new(), paths: Vec::new() }
    }
}

/// Full lint configuration (one [`RuleCfg`] per rule id).
#[derive(Debug, Clone)]
pub struct Config {
    rules: BTreeMap<String, RuleCfg>,
}

impl Default for Config {
    /// Built-in defaults mirroring the shipped `rust/detlint.toml`, so the
    /// pass is meaningful even with no config file on disk.
    fn default() -> Self {
        let mut rules: BTreeMap<String, RuleCfg> = BTreeMap::new();
        for r in RULES {
            rules.insert(r.to_string(), RuleCfg::default());
        }
        let set = |rules: &mut BTreeMap<String, RuleCfg>, id: &str, allow: &[&str], paths: &[&str]| {
            let c = rules.get_mut(id).expect("all five rules were just inserted");
            c.allow = allow.iter().map(|s| s.to_string()).collect();
            c.paths = paths.iter().map(|s| s.to_string()).collect();
        };
        set(&mut rules, "wall-clock", &["src/main.rs", "src/bench/", "benches/", "examples/"], &[]);
        set(&mut rules, "unseeded-rng", &["src/util/rng.rs", "src/testing/"], &[]);
        set(
            &mut rules,
            "dispatch-unwrap",
            &[],
            &[
                "src/coordinator/pool.rs",
                "src/coordinator/server.rs",
                "src/coordinator/batcher.rs",
                "src/coordinator/trace.rs",
            ],
        );
        set(
            &mut rules,
            "worker-dependent-decision",
            &[],
            &["src/coordinator/fault.rs", "src/coordinator/sched.rs"],
        );
        Config { rules }
    }
}

impl Config {
    /// Parse the `detlint.toml` subset: `[rule-id]` sections with
    /// `severity = "deny"`, `allow = ["path", ...]`, `paths = [...]` keys.
    /// Unknown sections and keys are errors — a typo'd config must not
    /// silently disable a rule.
    pub fn from_toml(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section: Option<String> = None;
        for (no, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim();
                if !RULES.contains(&name) {
                    return Err(format!(
                        "line {}: unknown rule section [{name}] (one of {})",
                        no + 1,
                        RULES.join(", ")
                    ));
                }
                section = Some(name.to_string());
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(format!("line {}: expected `key = value`, got {line:?}", no + 1));
            };
            let Some(sec) = &section else {
                return Err(format!("line {}: key outside a [rule] section", no + 1));
            };
            let rule = cfg.rules.get_mut(sec).expect("sections are validated above");
            let key = key.trim();
            let value = value.trim();
            match key {
                "severity" => rule.severity = Severity::parse(&parse_str(value, no)?)?,
                "allow" => rule.allow = parse_str_list(value, no)?,
                "paths" => rule.paths = parse_str_list(value, no)?,
                other => {
                    return Err(format!(
                        "line {}: unknown key {other:?} (severity|allow|paths)",
                        no + 1
                    ))
                }
            }
        }
        Ok(cfg)
    }

    /// Load from a config file path.
    pub fn from_path(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Config::from_toml(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    fn rule(&self, id: &str) -> &RuleCfg {
        self.rules.get(id).expect("all five rules exist in every Config")
    }

    /// Mutable access for programmatic configs (tests).
    pub fn rule_mut(&mut self, id: &str) -> &mut RuleCfg {
        self.rules.get_mut(id).expect("all five rules exist in every Config")
    }
}

fn parse_str(value: &str, no: usize) -> Result<String, String> {
    let v = value.trim();
    v.strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .map(|s| s.to_string())
        .ok_or_else(|| format!("line {}: expected a quoted string, got {v:?}", no + 1))
}

fn parse_str_list(value: &str, no: usize) -> Result<Vec<String>, String> {
    let v = value.trim();
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .ok_or_else(|| format!("line {}: expected a [\"...\"] list, got {v:?}", no + 1))?;
    let mut out = Vec::new();
    for item in inner.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        out.push(parse_str(item, no)?);
    }
    Ok(out)
}

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path as given to the linter (normalized `/` separators).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// 1-based column of the offending token.
    pub column: usize,
    /// Rule id (or [`MALFORMED_ALLOW`]).
    pub rule: String,
    /// Configured severity at report time.
    pub severity: Severity,
    /// Human explanation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} {} {}", self.file, self.line, self.rule, self.message)
    }
}

/// Whether `path` (normalized) matches any configured path fragment.
fn matches_any(path: &str, fragments: &[String]) -> bool {
    fragments.iter().any(|f| path.contains(f.as_str()))
}

/// A source file after lexical scrubbing.
struct Scrubbed {
    /// Code lines with comments/strings/char literals blanked.
    lines: Vec<String>,
    /// `(line, text)` of every comment, for allow-marker parsing.
    comments: Vec<(usize, String)>,
    /// Per-line: inside a `#[cfg(test)]` / `#[test]` brace region.
    in_test: Vec<bool>,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Blank comments, string literals and char literals (newlines kept so
/// line numbers survive), collecting comment text. Handles nested block
/// comments, escapes, raw/byte strings (`r"…"`, `r#"…"#`, `b"…"`,
/// `br#"…"#`) and the char-literal/lifetime ambiguity.
fn scrub(source: &str) -> Scrubbed {
    let chars: Vec<char> = source.chars().collect();
    let mut out = String::with_capacity(source.len());
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let mut prev_code: Option<char> = None;
    // Blank `n` chars starting at `i`, preserving newlines.
    let blank = |out: &mut String, line: &mut usize, chars: &[char], from: usize, to: usize| {
        for &c in &chars[from..to] {
            if c == '\n' {
                *line += 1;
                out.push('\n');
            } else {
                out.push(' ');
            }
        }
    };
    while i < chars.len() {
        let c = chars[i];
        // Raw / byte string openers: r", r#", b", br", br#" — only when
        // the prefix letters are not the tail of a longer identifier.
        if (c == 'r' || c == 'b') && !prev_code.is_some_and(is_ident) {
            let mut j = i + 1;
            if c == 'b' && chars.get(j) == Some(&'r') {
                j += 1;
            }
            let raw = chars.get(j.wrapping_sub(1)) == Some(&'r') || c == 'r';
            let mut hashes = 0usize;
            while raw && chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if chars.get(j) == Some(&'"') && (raw || c == 'b') {
                // Emit the opener verbatim, blank the contents.
                for &p in &chars[i..=j] {
                    out.push(p);
                }
                let body = j + 1;
                let mut k = body;
                'scan: while k < chars.len() {
                    if chars[k] == '"' && !raw {
                        // plain b"…": honor escapes
                        break 'scan;
                    }
                    if chars[k] == '"' && raw {
                        let mut h = 0usize;
                        while chars.get(k + 1 + h) == Some(&'#') {
                            h += 1;
                        }
                        if h >= hashes {
                            break 'scan;
                        }
                    }
                    if chars[k] == '\\' && !raw {
                        k += 1;
                    }
                    k += 1;
                }
                blank(&mut out, &mut line, &chars, body, k.min(chars.len()));
                // closer: `"` plus hashes
                let close_end = (k + 1 + hashes).min(chars.len());
                for &p in chars.get(k..close_end).unwrap_or(&[]) {
                    out.push(p);
                }
                i = close_end;
                prev_code = Some('"');
                continue;
            }
        }
        match c {
            '\n' => {
                line += 1;
                out.push('\n');
                i += 1;
            }
            '/' if chars.get(i + 1) == Some(&'/') => {
                let start = i;
                while i < chars.len() && chars[i] != '\n' {
                    i += 1;
                }
                comments.push((line, chars[start..i].iter().collect()));
                blank(&mut out, &mut line, &chars, start, i);
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                let start_line = line;
                let start = i;
                let mut depth = 1usize;
                i += 2;
                while i < chars.len() && depth > 0 {
                    if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                comments.push((start_line, chars[start..i].iter().collect()));
                blank(&mut out, &mut line, &chars, start, i);
            }
            '"' => {
                out.push('"');
                let body = i + 1;
                let mut k = body;
                while k < chars.len() && chars[k] != '"' {
                    if chars[k] == '\\' {
                        k += 1;
                    }
                    k += 1;
                }
                blank(&mut out, &mut line, &chars, body, k.min(chars.len()));
                if k < chars.len() {
                    out.push('"');
                    k += 1;
                }
                i = k;
                prev_code = Some('"');
            }
            '\'' => {
                // Char literal vs lifetime: `'\…'` and `'x'` are literals;
                // anything else (`'a` in `<'a>`, `'static`) is a lifetime.
                let is_char = match chars.get(i + 1) {
                    Some('\\') => true,
                    Some(_) => chars.get(i + 2) == Some(&'\''),
                    None => false,
                };
                if is_char {
                    out.push('\'');
                    let body = i + 1;
                    let mut k = body;
                    while k < chars.len() && chars[k] != '\'' {
                        if chars[k] == '\\' {
                            k += 1;
                        }
                        k += 1;
                    }
                    blank(&mut out, &mut line, &chars, body, k.min(chars.len()));
                    if k < chars.len() {
                        out.push('\'');
                        k += 1;
                    }
                    i = k;
                } else {
                    out.push('\'');
                    i += 1;
                }
                prev_code = Some('\'');
            }
            _ => {
                out.push(c);
                if !c.is_whitespace() {
                    prev_code = Some(c);
                }
                i += 1;
            }
        }
    }
    let lines: Vec<String> = out.lines().map(|l| l.to_string()).collect();
    let in_test = mark_test_regions(&lines);
    Scrubbed { lines, comments, in_test }
}

/// Mark lines inside `#[cfg(test)]` / `#[test]` brace regions (the item
/// following the attribute, tracked by brace depth on scrubbed text).
fn mark_test_regions(lines: &[String]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth = 0i64;
    let mut skip_at: Option<i64> = None;
    let mut pending = false;
    for (idx, l) in lines.iter().enumerate() {
        if skip_at.is_some() {
            in_test[idx] = true;
        }
        if skip_at.is_none() && (l.contains("#[cfg(test)]") || l.contains("#[test]")) {
            pending = true;
            in_test[idx] = true;
        }
        for ch in l.chars() {
            match ch {
                '{' => {
                    if pending && skip_at.is_none() {
                        skip_at = Some(depth);
                        pending = false;
                        in_test[idx] = true;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if skip_at == Some(depth) {
                        skip_at = None;
                    }
                }
                _ => {}
            }
        }
        if pending {
            in_test[idx] = true; // attribute lines before the opening brace
        }
    }
    in_test
}

/// A parsed `detlint::allow(rule, reason)` marker.
struct AllowMarker {
    line: usize,
    rule: String,
    reason: String,
}

fn parse_allow_markers(comments: &[(usize, String)]) -> Vec<AllowMarker> {
    let mut markers = Vec::new();
    for (line, text) in comments {
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("detlint::allow(") {
            let after = &rest[pos + "detlint::allow(".len()..];
            let Some(close) = after.find(')') else { break };
            let body = &after[..close];
            let (rule, reason) = match body.split_once(',') {
                Some((r, why)) => (r.trim().to_string(), why.trim().to_string()),
                None => (body.trim().to_string(), String::new()),
            };
            markers.push(AllowMarker { line: *line, rule, reason });
            rest = &after[close + 1..];
        }
    }
    markers
}

/// Identifier tokens of a scrubbed line with 0-based columns plus the
/// nearest non-space neighbors (for `.unwrap()` / `panic!` shapes).
struct Tok<'a> {
    text: &'a str,
    col: usize,
    prev: Option<char>,
    next: Option<char>,
}

fn tokens(line: &str) -> Vec<Tok<'_>> {
    let bytes = line.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if is_ident(c) && !c.is_ascii_digit() {
            let start = i;
            while i < bytes.len() && is_ident(bytes[i] as char) {
                i += 1;
            }
            let prev = line[..start].trim_end().chars().next_back();
            let next = line[i..].trim_start().chars().next();
            toks.push(Tok { text: &line[start..i], col: start, prev, next });
        } else {
            i += 1;
        }
    }
    toks
}

const WALL_CLOCK_TYPES: [&str; 2] = ["Instant", "SystemTime"];
const UNORDERED_TYPES: [&str; 4] = ["HashMap", "HashSet", "hash_map", "hash_set"];
const ENTROPY_SOURCES: [&str; 5] =
    ["thread_rng", "from_entropy", "OsRng", "RandomState", "getrandom"];
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
/// Exact identifiers that make a decision worker-shape-dependent. Matched
/// whole, so counters like `worker_panics` never false-positive.
const WORKER_IDENTITY: [&str; 12] = [
    "worker_id",
    "worker_ids",
    "worker_index",
    "worker_count",
    "workers",
    "nworkers",
    "n_workers",
    "num_workers",
    "thread_id",
    "ThreadId",
    "thread",
    "available_parallelism",
];

/// Lint one file's source. `label` is the path used for scoping, allow
/// matching and reporting (normalized to `/` separators).
pub fn lint_source(label: &str, source: &str, cfg: &Config) -> Vec<Finding> {
    let label = label.replace('\\', "/");
    let scrubbed = scrub(source);
    let markers = parse_allow_markers(&scrubbed.comments);
    let mut findings: Vec<Finding> = Vec::new();

    // Malformed markers are findings themselves (always deny).
    for m in &markers {
        if !RULES.contains(&m.rule.as_str()) {
            findings.push(Finding {
                file: label.clone(),
                line: m.line,
                column: 1,
                rule: MALFORMED_ALLOW.to_string(),
                severity: Severity::Deny,
                message: format!(
                    "detlint::allow names unknown rule {:?} (one of {})",
                    m.rule,
                    RULES.join(", ")
                ),
            });
        } else if m.reason.is_empty() {
            findings.push(Finding {
                file: label.clone(),
                line: m.line,
                column: 1,
                rule: MALFORMED_ALLOW.to_string(),
                severity: Severity::Deny,
                message: format!(
                    "detlint::allow({}) requires a justification: detlint::allow({}, reason)",
                    m.rule, m.rule
                ),
            });
        }
    }
    // A valid marker suppresses its rule on its own line and the next.
    let suppressed = |rule: &str, line: usize| {
        markers.iter().any(|m| {
            m.rule == rule && !m.reason.is_empty() && (m.line == line || m.line + 1 == line)
        })
    };

    let mut emit = |rule: &str, severity: Severity, line: usize, col: usize, message: String| {
        if severity == Severity::Off || suppressed(rule, line) {
            return;
        }
        findings.push(Finding {
            file: label.clone(),
            line,
            column: col + 1,
            rule: rule.to_string(),
            severity,
            message,
        });
    };

    let wall = cfg.rule("wall-clock");
    let unordered = cfg.rule("unordered-iter");
    let rng = cfg.rule("unseeded-rng");
    let unwrap = cfg.rule("dispatch-unwrap");
    let worker = cfg.rule("worker-dependent-decision");
    let wall_on = !matches_any(&label, &wall.allow);
    let unordered_on = !matches_any(&label, &unordered.allow);
    let rng_on = !matches_any(&label, &rng.allow);
    let unwrap_on = (unwrap.paths.is_empty() || matches_any(&label, &unwrap.paths))
        && !matches_any(&label, &unwrap.allow);
    let worker_on = (worker.paths.is_empty() || matches_any(&label, &worker.paths))
        && !matches_any(&label, &worker.allow);

    for (idx, code) in scrubbed.lines.iter().enumerate() {
        if scrubbed.in_test[idx] {
            continue;
        }
        let lineno = idx + 1;
        for t in tokens(code) {
            if wall_on && WALL_CLOCK_TYPES.contains(&t.text) {
                emit(
                    "wall-clock",
                    wall.severity,
                    lineno,
                    t.col,
                    format!(
                        "wall-clock type `{}` outside the timing allowlist; deterministic \
                         paths must use the virtual clock",
                        t.text
                    ),
                );
            }
            if unordered_on && UNORDERED_TYPES.contains(&t.text) {
                emit(
                    "unordered-iter",
                    unordered.severity,
                    lineno,
                    t.col,
                    format!(
                        "`{}` has nondeterministic iteration order; use BTreeMap/BTreeSet \
                         or justify with detlint::allow(unordered-iter, reason)",
                        t.text
                    ),
                );
            }
            if rng_on && ENTROPY_SOURCES.contains(&t.text) {
                emit(
                    "unseeded-rng",
                    rng.severity,
                    lineno,
                    t.col,
                    format!(
                        "entropy source `{}` outside util/rng; all randomness must be \
                         seeded Pcg32 streams",
                        t.text
                    ),
                );
            }
            if unwrap_on {
                let method_panic =
                    (t.text == "unwrap" || t.text == "expect") && t.prev == Some('.');
                let macro_panic = PANIC_MACROS.contains(&t.text) && t.next == Some('!');
                if method_panic || macro_panic {
                    emit(
                        "dispatch-unwrap",
                        unwrap.severity,
                        lineno,
                        t.col,
                        format!(
                            "`{}` in the supervised dispatch path; route the failure \
                             through BatchResult.outcome / ServeError instead",
                            t.text
                        ),
                    );
                }
            }
            if worker_on && WORKER_IDENTITY.contains(&t.text) {
                emit(
                    "worker-dependent-decision",
                    worker.severity,
                    lineno,
                    t.col,
                    format!(
                        "`{}` reachable from fault/scheduling decisions; outcomes must be \
                         pure functions of (request_id, arrival_tick, attempt)",
                        t.text
                    ),
                );
            }
        }
    }
    findings
}

/// Recursively collect `.rs` files under `root` (or `root` itself when it
/// is a file), in sorted order. `fixtures/` and `target/` directories are
/// skipped — fixture files trip rules by design.
pub fn collect_rs_files(root: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(root)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if name == "fixtures" || name == "target" {
                continue;
            }
            out.extend(collect_rs_files(&path)?);
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(out)
}

/// Lint every `.rs` file under the given paths. Findings come back sorted
/// `(file, line, column, rule)` — deterministic output is part of the
/// lint's own contract.
pub fn lint_paths(paths: &[PathBuf], cfg: &Config) -> std::io::Result<Vec<Finding>> {
    let mut findings = Vec::new();
    for root in paths {
        for file in collect_rs_files(root)? {
            let source = std::fs::read_to_string(&file)?;
            let label = file.to_string_lossy().replace('\\', "/");
            findings.extend(lint_source(&label, &source, cfg));
        }
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.column, &a.rule).cmp(&(&b.file, b.line, b.column, &b.rule))
    });
    Ok(findings)
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable output: a JSON array of finding objects.
pub fn to_json(findings: &[Finding]) -> String {
    let mut out = String::from("[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n  {{\"file\": \"{}\", \"line\": {}, \"column\": {}, \"rule\": \"{}\", \
             \"severity\": \"{}\", \"message\": \"{}\"}}",
            json_escape(&f.file),
            f.line,
            f.column,
            json_escape(&f.rule),
            f.severity.name(),
            json_escape(&f.message)
        ));
    }
    out.push_str(if findings.is_empty() { "]" } else { "\n]" });
    out
}

/// True when any finding is at deny severity (the failing-gate condition).
pub fn any_deny(findings: &[Finding]) -> bool {
    findings.iter().any(|f| f.severity == Severity::Deny)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(label: &str, src: &str) -> Vec<Finding> {
        lint_source(label, src, &Config::default())
    }

    #[test]
    fn scrubber_blanks_strings_comments_chars() {
        let src = "let a = \"Instant::now() HashMap\"; // HashMap in comment\nlet c = 'H'; let l: &'static str = x;\n/* Instant */ let d = 1;\n";
        assert!(lint("x.rs", src).is_empty(), "{:?}", lint("x.rs", src));
    }

    #[test]
    fn scrubber_handles_raw_and_byte_strings() {
        let src = "let a = r#\"Instant HashMap \"quoted\" \"#;\nlet b = b\"SystemTime\";\nlet c = br#\"thread_rng\"#;\n";
        assert!(lint("x.rs", src).is_empty(), "{:?}", lint("x.rs", src));
    }

    #[test]
    fn wall_clock_flags_instant_and_allowlists() {
        let src = "use std::time::Instant;\n";
        let f = lint("src/coordinator/pool.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "wall-clock");
        assert_eq!(f[0].line, 1);
        assert!(lint("src/main.rs", src).is_empty(), "main.rs is allowlisted");
        assert!(lint("src/bench/mod.rs", src).is_empty());
    }

    #[test]
    fn unordered_iter_flags_hashmap_everywhere() {
        let src = "use std::collections::HashMap;\nlet m: HashMap<u32, u32> = HashMap::new();\n";
        let f = lint("src/arch/epa.rs", src);
        assert_eq!(f.len(), 3, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "unordered-iter"));
    }

    #[test]
    fn dispatch_unwrap_scoped_to_dispatch_path() {
        let src = "let x = m.lock().unwrap();\nlet y = o.expect(\"msg\");\npanic!(\"boom\");\nunreachable!();\n";
        let f = lint("src/coordinator/pool.rs", src);
        assert_eq!(f.len(), 4, "{f:?}");
        assert!(lint("src/arch/epa.rs", src).is_empty(), "rule is path-scoped");
    }

    #[test]
    fn dispatch_unwrap_ignores_unwrap_or_else_and_asserts() {
        let src = "let x = m.lock().unwrap_or_else(|p| p.into_inner());\nlet y = h.join().unwrap_or(true);\nassert_eq!(a, b);\nassert!(x > 0);\ndebug_assert!(ok);\n";
        assert!(lint("src/coordinator/pool.rs", src).is_empty());
    }

    #[test]
    fn worker_identity_exact_tokens_only() {
        let trip = "let shard = req_id % n_workers as u64;\n";
        let f = lint("src/coordinator/fault.rs", trip);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "worker-dependent-decision");
        let pass = "stats.worker_panics += other.worker_panics;\n";
        assert!(lint("src/coordinator/fault.rs", pass).is_empty(), "substring must not match");
        assert!(lint("src/coordinator/pool.rs", trip).is_empty(), "rule is path-scoped");
    }

    #[test]
    fn unseeded_rng_flags_entropy_sources() {
        let src = "let mut rng = rand::thread_rng();\n";
        let f = lint("src/snn/sda.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "unseeded-rng");
        assert!(lint("src/util/rng.rs", src).is_empty(), "util/rng is the sanctioned module");
    }

    #[test]
    fn allow_marker_suppresses_same_and_next_line() {
        let src = "// detlint::allow(unordered-iter, profiling scratch never reaches output)\nuse std::collections::HashMap;\n";
        assert!(lint("src/arch/epa.rs", src).is_empty());
        let inline = "let m = HashMap::new(); // detlint::allow(unordered-iter, scratch)\n";
        assert!(lint("src/arch/epa.rs", inline).is_empty());
    }

    #[test]
    fn allow_marker_without_reason_is_a_finding() {
        let src = "// detlint::allow(unordered-iter)\nuse std::collections::HashMap;\n";
        let f = lint("src/arch/epa.rs", src);
        assert_eq!(f.len(), 2, "bare marker reports itself AND fails to suppress: {f:?}");
        assert!(f.iter().any(|x| x.rule == MALFORMED_ALLOW));
        assert!(f.iter().any(|x| x.rule == "unordered-iter"));
    }

    #[test]
    fn allow_marker_unknown_rule_is_a_finding() {
        let src = "// detlint::allow(wibble, because)\n";
        let f = lint("x.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, MALFORMED_ALLOW);
    }

    #[test]
    fn test_regions_are_skipped() {
        let src = "pub fn live() {}\n#[cfg(test)]\nmod tests {\n    use std::collections::HashMap;\n    #[test]\n    fn t() { let _ = HashMap::new(); x.unwrap(); }\n}\n";
        assert!(lint("src/coordinator/pool.rs", src).is_empty(), "test code is exempt");
    }

    #[test]
    fn code_after_test_region_still_linted() {
        let src = "#[cfg(test)]\nmod tests {\n}\nuse std::collections::HashMap;\n";
        let f = lint("src/arch/wmu.rs", src);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn severity_and_config_parsing() {
        let toml = "# comment\n[wall-clock]\nseverity = \"warn\"\nallow = [\"src/special.rs\"]\n\n[dispatch-unwrap]\npaths = [\"src/x.rs\"]\n";
        let cfg = Config::from_toml(toml).unwrap();
        let f = lint_source("src/a.rs", "use std::time::Instant;\n", &cfg);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].severity, Severity::Warn);
        assert!(!any_deny(&f), "warn findings never fail the gate");
        assert!(lint_source("src/special.rs", "use std::time::Instant;\n", &cfg).is_empty());
        assert!(Config::from_toml("[nope]\n").is_err(), "unknown section must error");
        assert!(Config::from_toml("[wall-clock]\nseverity = \"loud\"\n").is_err());
        assert!(Config::from_toml("[wall-clock]\nwibble = \"x\"\n").is_err());
    }

    #[test]
    fn off_severity_disables_rule() {
        let cfg = Config::from_toml("[wall-clock]\nseverity = \"off\"\n").unwrap();
        assert!(lint_source("src/a.rs", "use std::time::Instant;\n", &cfg).is_empty());
    }

    #[test]
    fn json_output_shape_and_escaping() {
        let f = lint("src/a.rs", "use std::time::Instant;\n");
        let json = to_json(&f);
        assert!(json.starts_with('['), "{json}");
        assert!(json.contains("\"rule\": \"wall-clock\""), "{json}");
        assert!(json.contains("\"line\": 1"), "{json}");
        assert_eq!(to_json(&[]), "[]");
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn findings_display_as_file_line_rule_message() {
        let f = lint("src/a.rs", "use std::time::Instant;\n");
        let line = f[0].to_string();
        assert!(line.starts_with("src/a.rs:1 wall-clock "), "{line}");
    }
}
