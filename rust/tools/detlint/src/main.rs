//! detlint CLI.
//!
//! ```text
//! detlint [--json] [--config PATH] PATH...
//! ```
//!
//! Walks each PATH (file or directory) for `.rs` sources, lints them
//! against the determinism rules, and prints findings as
//! `file:line rule message` (or a JSON array with `--json`).
//!
//! Exit codes: 0 = clean (or warn-only findings), 1 = at least one
//! deny-severity finding, 2 = usage/config error.
//!
//! Config resolution: `--config PATH` if given, else `./detlint.toml`
//! if present, else built-in defaults.

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::{any_deny, lint_paths, to_json, Config};

fn usage() -> ExitCode {
    eprintln!("usage: detlint [--json] [--config PATH] PATH...");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let mut json = false;
    let mut config_path: Option<PathBuf> = None;
    let mut paths: Vec<PathBuf> = Vec::new();

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--config" => match args.next() {
                Some(p) => config_path = Some(PathBuf::from(p)),
                None => return usage(),
            },
            "--help" | "-h" => {
                println!("detlint [--json] [--config PATH] PATH...");
                println!("rules: {}", detlint::RULES.join(", "));
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("detlint: unknown flag {other}");
                return usage();
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        return usage();
    }

    let cfg = if let Some(p) = &config_path {
        match Config::from_path(p) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("detlint: {e}");
                return ExitCode::from(2);
            }
        }
    } else {
        let default_path = PathBuf::from("detlint.toml");
        if default_path.is_file() {
            match Config::from_path(&default_path) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("detlint: {e}");
                    return ExitCode::from(2);
                }
            }
        } else {
            Config::default()
        }
    };

    let findings = match lint_paths(&paths, &cfg) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };

    if json {
        println!("{}", to_json(&findings));
    } else {
        for f in &findings {
            println!("{f}");
        }
        if !findings.is_empty() {
            let denies = findings
                .iter()
                .filter(|f| f.severity == detlint::Severity::Deny)
                .count();
            eprintln!(
                "detlint: {} finding(s), {} at deny severity",
                findings.len(),
                denies
            );
        }
    }

    if any_deny(&findings) {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
