// Fixture: fixed twin of trip_unseeded_rng — MUST pass. All randomness
// comes from an explicitly seeded stream.

pub fn jitter(seed: u64) -> u64 {
    let mut rng = crate::util::rng::Pcg32::new(seed, 0xda3e39cb94b95bdb);
    rng.next_u64() % 100
}
