// Fixture: fixed twin of trip_wall_clock — MUST pass. Time flows from
// the virtual clock, never the host.

pub fn measure(work: impl Fn(), tick_before: u64, tick_after: u64) -> u64 {
    work();
    tick_after - tick_before
}
