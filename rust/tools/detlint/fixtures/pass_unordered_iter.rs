// Fixture: fixed twin of trip_unordered_iter — MUST pass. BTreeMap
// iterates in key order, so the report is deterministic.

use std::collections::BTreeMap;

pub fn report(counts: &BTreeMap<String, u64>) -> String {
    let mut out = String::new();
    for (k, v) in counts {
        out.push_str(&format!("{k}={v}\n"));
    }
    out
}
