// Fixture: MUST trip `worker-dependent-decision` (scoped onto this file
// by fixtures.toml) — a fault decision keyed on worker identity changes
// with pool size, breaking cross-worker-count bit-identity.

pub fn should_inject(req_id: u64, worker_id: usize, n_workers: usize) -> bool {
    (req_id as usize + worker_id) % n_workers == 0
}
