// Fixture: MUST pass — rules do not apply inside #[cfg(test)] / #[test]
// regions; test scaffolding may use HashMap, unwrap, and wall time.

pub fn live(x: u32) -> u32 {
    x + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;
    use std::time::Instant;

    #[test]
    fn scaffolding_is_exempt() {
        let t0 = Instant::now();
        let mut m = HashMap::new();
        m.insert(1u32, live(1));
        assert_eq!(*m.get(&1).unwrap(), 2);
        assert!(t0.elapsed().as_secs() < 60);
    }
}
