// Fixture: fixed twin of trip_dispatch_unwrap (same fixtures.toml
// scoping) — MUST pass. Failures are routed through the result channel,
// and poisoned-lock recovery via unwrap_or_else is allowed.

pub fn dispatch(slot: Option<u32>) -> Result<u32, String> {
    let Some(v) = slot else {
        return Err("slot was never filled".to_string());
    };
    if v == 0 {
        return Err("zero slot".to_string());
    }
    Ok(v)
}

pub fn drain(lock: &std::sync::Mutex<Vec<u32>>) -> Vec<u32> {
    let mut q = lock.lock().unwrap_or_else(|p| p.into_inner());
    std::mem::take(&mut *q)
}
