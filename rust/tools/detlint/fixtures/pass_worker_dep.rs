// Fixture: fixed twin of trip_worker_dep (same fixtures.toml scoping) —
// MUST pass. The decision is a pure function of the request's identity
// and attempt, so any worker reaches the same verdict.

pub fn should_inject(req_id: u64, arrival_tick: u64, attempt: u32) -> bool {
    let h = req_id
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(arrival_tick)
        .wrapping_add(attempt as u64);
    h % 17 == 0
}
