// Fixture: MUST trip `unseeded-rng` — entropy-seeded randomness outside
// util/rng makes runs irreproducible.

pub fn jitter() -> u64 {
    let mut rng = rand::thread_rng();
    rng.gen_range(0..100)
}
