// Fixture: MUST pass — a justified detlint::allow marker suppresses the
// rule on its own line and the next.

// detlint::allow(unordered-iter, profiling scratch; never reaches Report or merged output)
use std::collections::HashMap;

// detlint::allow(unordered-iter, local scratch drained through a sorted Vec before output)
pub fn scratch() -> HashMap<u32, u32> {
    // detlint::allow(unordered-iter, local scratch drained through a sorted Vec before output)
    HashMap::new()
}
