// Fixture: MUST trip `unordered-iter` — HashMap iteration order reaches
// the returned report.

use std::collections::HashMap;

pub fn report(counts: &HashMap<String, u64>) -> String {
    let mut out = String::new();
    for (k, v) in counts {
        out.push_str(&format!("{k}={v}\n"));
    }
    out
}
