// Fixture: MUST trip `dispatch-unwrap` (scoped onto this file by
// fixtures.toml) — panics in the supervised dispatch path kill workers
// instead of surfacing as ServeError.

pub fn dispatch(slot: Option<u32>) -> u32 {
    let v = slot.expect("slot must be filled");
    if v == 0 {
        panic!("zero slot");
    }
    v
}
