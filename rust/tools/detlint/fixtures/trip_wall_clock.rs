// Fixture: MUST trip `wall-clock` — Instant in result-affecting code.

use std::time::Instant;

pub fn measure(work: impl Fn()) -> f64 {
    let t0 = Instant::now();
    work();
    t0.elapsed().as_secs_f64()
}
