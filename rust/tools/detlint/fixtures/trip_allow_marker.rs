// Fixture: MUST trip — a detlint::allow marker without a reason is
// itself a finding (malformed-allow) AND fails to suppress the rule.

// detlint::allow(unordered-iter)
use std::collections::HashMap;

pub fn scratch() -> HashMap<u32, u32> {
    // detlint::allow(unordered-iter)
    HashMap::new()
}
