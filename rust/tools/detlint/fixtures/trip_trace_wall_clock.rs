// Fixture: MUST trip `wall-clock` — a wall-time stamp in a trace event.
// Trace timestamps are virtual ticks / device cycles; reading the host
// clock to fill `ts` makes the exported trace machine-dependent.

use std::time::SystemTime;

pub fn trace_event(name: &str) -> String {
    let ts = SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_micros())
        .unwrap_or(0);
    format!("{{\"name\":\"{name}\",\"ph\":\"i\",\"ts\":{ts}}}")
}
