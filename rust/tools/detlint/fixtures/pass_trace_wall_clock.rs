// Fixture: fixed twin of trip_trace_wall_clock — MUST pass. The trace
// timestamp is the caller's virtual-clock tick, never the host clock.

pub fn trace_event(name: &str, tick: u64) -> String {
    format!("{{\"name\":\"{name}\",\"ph\":\"i\",\"ts\":{tick}}}")
}
